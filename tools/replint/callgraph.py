"""Function index + traced-reachability call graph for replint.

R003 (host-sync-in-traced-code) needs to know which functions can end up
inside a jax trace. Exact dynamic dispatch is undecidable statically, so
the graph is built *conservatively* — over-approximating reachability is
safe for R003 (a host sync flagged in a function that is also called from
host code is still a landmine: the traced caller exists).

Model
-----
* Every ``def`` (top-level, method, nested) and every ``lambda`` in the
  project is a node, keyed ``module:qualname`` (lambdas get
  ``<lambda@line>``).
* **Traced entries** are functions that jax traces directly:

  - decorated with ``jit`` / ``pmap`` (bare, dotted, or wrapped in
    ``functools.partial(jax.jit, ...)``), or
  - passed as a function argument to a tracing combinator —
    ``jax.jit(f)``, ``lax.scan(body, ...)``, ``shard_map(f, ...)`` (both
    the module function and the ``TwinSharding.shard_map`` method),
    ``vmap``, ``pmap``, ``cond``, ``switch``, ``while_loop``,
    ``fori_loop``, ``checkpoint`` / ``remat``, ``pallas_call``, ``grad`` /
    ``value_and_grad``.

* **Edges** go from a function to every project function it *references*
  (calls OR mentions — a mentioned function is usually passed onward into
  a trace, e.g. ``functools.partial(latency.t_cmp, params)`` handed to a
  ``shard_map`` helper), and to its lexically nested defs/lambdas.
* Reachability is the BFS closure of the entries over these edges.

Name resolution covers the idioms this repo actually uses: plain names
(enclosing scopes, module globals), ``from mod import f [as g]``,
``import pkg.mod as alias`` + ``alias.f``, and ``functools.partial(f, …)``
unwrapping. Unresolvable callees (third-party, ``self.x``, dynamic) are
ignored.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

#: decorator / combinator names that put their function argument(s) in a trace
TRACING_DECORATORS = {"jit", "pmap"}
TRACING_CALLS = {
    "jit", "pmap", "vmap", "scan", "shard_map", "cond", "switch",
    "while_loop", "fori_loop", "checkpoint", "remat", "pallas_call",
    "grad", "value_and_grad",
}


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_name(node: ast.AST) -> Optional[str]:
    """Rightmost component of a call target (``scan`` for ``jax.lax.scan``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def unwrap_partial(node: ast.AST) -> ast.AST:
    """``functools.partial(f, ...)`` -> ``f`` (recursively), else ``node``."""
    while (isinstance(node, ast.Call) and last_name(node.func) == "partial"
           and node.args):
        node = node.args[0]
    return node


def partial_bound_args(node: ast.AST) -> int:
    """Number of positional args a ``functools.partial`` wrapper binds
    (0 when ``node`` is not a partial call)."""
    if isinstance(node, ast.Call) and last_name(node.func) == "partial":
        return len(node.args) - 1
    return 0


class FuncInfo:
    """One function definition (or lambda) in the project."""

    __slots__ = ("module", "qual", "node", "parent")

    def __init__(self, module: str, qual: str, node: FuncNode,
                 parent: Optional[str]):
        self.module = module
        self.qual = qual
        self.node = node
        self.parent = parent  # enclosing function's qual, or None

    @property
    def key(self) -> str:
        return f"{self.module}:{self.qual}"

    @property
    def name(self) -> str:
        return self.qual.rsplit(".", 1)[-1]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FuncInfo({self.key})"


class _Indexer(ast.NodeVisitor):
    """Collects function defs, lambdas, and the module import table."""

    def __init__(self, module: str):
        self.module = module
        self.functions: Dict[str, FuncInfo] = {}
        self.imports: Dict[str, str] = {}
        self._stack: List[str] = []

    # -- imports ------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            name = alias.asname or alias.name.split(".")[0]
            self.imports[name] = alias.name if alias.asname else \
                alias.name.split(".")[0]

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level:  # relative import: resolve against this module
            base = self.module.split(".")
            base = base[: len(base) - node.level]
            prefix = ".".join(base + ([node.module] if node.module else []))
        else:
            prefix = node.module or ""
        for alias in node.names:
            if alias.name == "*":
                continue
            self.imports[alias.asname or alias.name] = \
                f"{prefix}.{alias.name}" if prefix else alias.name

    # -- defs ---------------------------------------------------------------
    def _add(self, name: str, node: FuncNode) -> str:
        qual = ".".join(self._stack + [name]) if self._stack else name
        parent = self._find_parent()
        self.functions[qual] = FuncInfo(self.module, qual, node, parent)
        return qual

    def _find_parent(self) -> Optional[str]:
        for i in range(len(self._stack), 0, -1):
            cand = ".".join(self._stack[:i])
            if cand in self.functions:
                return cand
        return None

    def _visit_scope(self, name: str, node: FuncNode) -> None:
        self._add(name, node)
        self._stack.append(name)
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self._stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_scope(node.name, node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_scope(node.name, node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._stack.append(node.name)
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self._stack.pop()

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_scope(f"<lambda@{node.lineno}>", node)


class CallGraph:
    """Project-wide function index + traced-entry reachability."""

    def __init__(self, project):
        self.project = project
        self.modules: Dict[str, _Indexer] = {}
        for sf in project.files:
            idx = _Indexer(sf.module)
            idx.visit(sf.tree)
            self.modules[sf.module] = idx
        self._edges: Dict[str, Set[str]] = {}
        self._traced: Set[str] = set()
        self._build()
        self._reachable = self._closure()

    # -- lookup -------------------------------------------------------------
    def functions_in(self, module: str) -> Iterable[FuncInfo]:
        idx = self.modules.get(module)
        return idx.functions.values() if idx else ()

    def owner_of(self, module: str, node: ast.AST) -> Optional[FuncInfo]:
        """Innermost function whose body lexically contains ``node``."""
        idx = self.modules.get(module)
        if idx is None:
            return None
        best, best_span = None, None
        for fi in idx.functions.values():
            fn = fi.node
            end = getattr(fn, "end_lineno", fn.lineno)
            if fn.lineno <= node.lineno <= end:
                span = end - fn.lineno
                if best_span is None or span < best_span:
                    best, best_span = fi, span
        return best

    def resolve(self, module: str, scope: Optional[str],
                node: ast.AST) -> Optional[FuncInfo]:
        """Resolve a function-valued expression to a project FuncInfo."""
        node = unwrap_partial(node)
        if isinstance(node, ast.Lambda):
            idx = self.modules.get(module)
            if idx:
                for fi in idx.functions.values():
                    if fi.node is node:
                        return fi
            return None
        path = dotted(node)
        if path is None:
            return None
        return self._resolve_dotted(module, scope, path)

    def _resolve_dotted(self, module: str, scope: Optional[str],
                        path: str) -> Optional[FuncInfo]:
        idx = self.modules.get(module)
        if idx is None:
            return None
        head, _, rest = path.partition(".")
        # 1. plain name: nested defs of the enclosing scope chain, then
        #    module-level functions
        if not rest:
            cur = scope
            while cur is not None:
                cand = idx.functions.get(f"{cur}.{head}")
                if cand is not None:
                    return cand
                cur = idx.functions[cur].parent if cur in idx.functions \
                    else None
            if head in idx.functions:
                return idx.functions[head]
        # 2. imported symbol (from mod import f as head / import mod as head)
        target = idx.imports.get(head)
        if target is None:
            return None
        full = f"{target}.{rest}" if rest else target
        # longest module prefix wins: "repro.core.latency.t_cmp" splits into
        # module "repro.core.latency" + qual "t_cmp"
        parts = full.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = ".".join(parts[:cut])
            if mod in self.modules:
                qual = ".".join(parts[cut:])
                return self.modules[mod].functions.get(qual)
        return None

    # -- graph construction -------------------------------------------------
    def _build(self) -> None:
        for module, idx in self.modules.items():
            for fi in idx.functions.values():
                self._edges.setdefault(fi.key, set())
                if self._has_tracing_decorator(fi.node):
                    self._traced.add(fi.key)
            # nested defs: outer -> inner
            for fi in idx.functions.values():
                if fi.parent is not None:
                    self._edges.setdefault(
                        f"{module}:{fi.parent}", set()).add(fi.key)
            self._scan_bodies(module, idx)

    def _has_tracing_decorator(self, node: FuncNode) -> bool:
        for dec in getattr(node, "decorator_list", ()):
            for sub in ast.walk(dec):
                if isinstance(sub, ast.Name) and sub.id in TRACING_DECORATORS:
                    return True
                if isinstance(sub, ast.Attribute) and \
                        sub.attr in TRACING_DECORATORS:
                    return True
        return False

    def _scan_bodies(self, module: str, idx: _Indexer) -> None:
        # walk each file once; attribute every expression to its innermost
        # enclosing function (module-level code belongs to no function and
        # can still *mark* traced entries)
        sf = self.project.by_module.get(module)
        if sf is None:
            return
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                owner = self.owner_of(module, node)
                scope = owner.qual if owner else None
                if last_name(node.func) in TRACING_CALLS:
                    for arg in list(node.args) + \
                            [kw.value for kw in node.keywords]:
                        fi = self.resolve(module, scope, arg)
                        if fi is not None:
                            self._traced.add(fi.key)
            # mentions: any reference to a project function from inside
            # another function adds an edge
            if isinstance(node, (ast.Name, ast.Attribute, ast.Lambda)):
                owner = self.owner_of(module, node)
                if owner is None:
                    continue
                if isinstance(node, ast.Lambda):
                    continue  # handled via nested-def edges
                fi = self.resolve(module, owner.qual, node)
                if fi is not None and fi.key != owner.key:
                    self._edges.setdefault(owner.key, set()).add(fi.key)

    def _closure(self) -> Set[str]:
        seen: Set[str] = set()
        frontier = list(self._traced)
        while frontier:
            cur = frontier.pop()
            if cur in seen:
                continue
            seen.add(cur)
            frontier.extend(self._edges.get(cur, ()))
        return seen

    # -- queries ------------------------------------------------------------
    def is_traced_entry(self, fi: FuncInfo) -> bool:
        return fi.key in self._traced

    def is_reachable(self, fi: FuncInfo) -> bool:
        """Can this function's body end up inside a jax trace?"""
        return fi.key in self._reachable

    @property
    def reachable_keys(self) -> Set[str]:
        return set(self._reachable)
