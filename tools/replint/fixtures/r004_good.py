"""R004 fixture: sharded scope uses twin_* helpers and the auto backend."""
import jax.numpy as jnp

from repro.core.sharding import twin_mean, twin_sum
from repro.kernels.segment_reduce import segment_reduce


def sharded_mean_load(data, assoc, m):
    per_bs = segment_reduce(data, assoc, m, backend="auto")
    return twin_mean(data) + twin_sum(per_bs * 0.0)


def host_summary(data):
    # outside sharded scope a plain reduction is fine
    return jnp.mean(data, axis=0)
