"""R001 fixture: dense one-hot contraction outside a named oracle."""
import jax.numpy as jnp


def per_bs_work(assoc, vals, m):
    onehot = jnp.eye(m)[assoc]  # expect: R001
    return onehot.T @ vals


def twin_counts(assoc, m):
    return jnp.sum(jnp.eye(m)[assoc], axis=0)  # expect: R001
