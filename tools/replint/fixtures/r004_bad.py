"""R004 fixture: raw cross-twin reductions / pinned backends inside sharded
scope."""
import jax.numpy as jnp

from repro.core.sharding import twin_sum  # noqa: F401
from repro.kernels.segment_reduce import segment_reduce


def sharded_mean_load(data, assoc, m):
    # sharded_* name puts the whole body in sharded scope
    per_bs = segment_reduce(data, assoc, m, backend="onehot")  # expect: R004
    return jnp.mean(data, axis=0)  # expect: R004


def run_round(ts, blk):
    def local(blk):
        return jnp.sum(blk, axis=0)  # expect: R004

    return ts.shard_map(local, blk)
