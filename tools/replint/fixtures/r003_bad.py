"""R003 fixture: host syncs inside functions reachable from a jit entry."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def jit_entry(x):
    # the jit entry: everything it mentions is traced-reachable
    return _accumulate(x)


def _accumulate(x):
    s = jnp.sum(x)
    total = float(s)  # expect: R003
    host = np.asarray(s)  # expect: R003
    return total + host.size + s.item()  # expect: R003


def scan_driver(xs):
    def body(carry, x):
        c = carry + jnp.tanh(x)
        c.block_until_ready()  # expect: R003
        return c, c

    return jax.lax.scan(body, jnp.zeros(()), xs)
