"""R005 fixture: structure- and dtype-stable scan carries."""
import functools

import jax
import jax.numpy as jnp


def stable_carry(xs):
    def body(carry, x):
        acc, count = carry
        return (acc + x, count + 1), acc

    return jax.lax.scan(body, (jnp.zeros(()), jnp.int32(0)), xs)


def lambda_body(xs):
    return jax.lax.scan(lambda c, x: (c + x, c), jnp.zeros(()), xs)


def partial_body(xs, scale):
    def body(scale, carry, x):
        return carry + scale * x, carry

    return jax.lax.scan(functools.partial(body, scale), jnp.zeros(()), xs)
