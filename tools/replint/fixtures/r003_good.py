"""R003 fixture: device-resident traced code; host syncs only at the host
boundary (functions the traced call graph never reaches)."""
import jax
import jax.numpy as jnp


@jax.jit
def jit_entry(x):
    return _accumulate(x)


def _accumulate(x):
    # stays a jnp scalar on device — no sync
    s = jnp.sum(x)
    return s / jnp.maximum(s, 1.0)


def _static_shapes(x):
    # trace-time Python arithmetic on static shape info is fine
    n = int(x.shape[0] * 0.5)
    return jnp.zeros((max(n, 1),))


def host_report(x):
    # never reachable from a traced entry: the host boundary may sync
    s = jnp.sum(x)
    return float(s)
