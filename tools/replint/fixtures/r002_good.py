"""R002 fixture: disciplined key handling — every consumption is fresh."""
import jax


def split_then_sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.uniform(k1, (3,))
    b = jax.random.normal(k2, (3,))
    return a + b


def rebind_chain(key):
    key, s1 = jax.random.split(key)
    x = jax.random.normal(s1, (2,))
    key, s2 = jax.random.split(key)
    return x + jax.random.normal(s2, (2,))


def fold_in_loop(key, n):
    # fold_in(key, i) is the sanctioned per-index derivation idiom
    out = []
    for i in range(n):
        out.append(jax.random.uniform(jax.random.fold_in(key, i), (2,)))
    return out


def subscript_keys(key):
    ks = jax.random.split(key, 3)
    a = jax.random.uniform(ks[0], (2,))
    b = jax.random.normal(ks[1], (2,))
    c = jax.random.gumbel(ks[2], (2,))
    return a, b, c


def loop_over_keys(key, xs):
    # the loop target is rebound fresh each iteration — never a reuse
    out = []
    for k in jax.random.split(key, len(xs)):
        out.append(jax.random.normal(k, (2,)))
    return out


def comprehension_keys(key):
    return [jax.random.normal(k, (2,))
            for k in jax.random.split(key, 4)]


def branch_keys(key, flag):
    if flag:
        return jax.random.uniform(key, (2,))
    return jax.random.normal(key, (2,))
