"""R002 fixture: PRNG keys consumed twice without a split/fold_in rebind."""
import jax


def double_sample(key):
    a = jax.random.uniform(key, (3,))
    b = jax.random.normal(key, (3,))  # expect: R002
    return a + b


def sample_after_split(key):
    subkeys = jax.random.split(key, 4)
    noise = jax.random.normal(key, (2,))  # expect: R002
    return subkeys, noise


def loop_reuse(key, n):
    out = []
    for _ in range(n):
        out.append(jax.random.uniform(key, (2,)))  # expect: R002
    return out


def subscript_reuse(key):
    ks = jax.random.split(key, 3)
    a = jax.random.uniform(ks[0], (2,))
    b = jax.random.normal(ks[0], (2,))  # expect: R002
    return a, b, ks[1]
