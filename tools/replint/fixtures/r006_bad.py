"""R006 fixture: jits of streaming round steps that fail to donate."""
import functools

import jax
import jax.numpy as jnp


def _round_step(cfg, state, keys):
    return state + jnp.tanh(keys), {"round_time": jnp.sum(state)}


step = jax.jit(_round_step, static_argnames=("cfg",))  # expect: R006

partial_step = jax.jit(  # expect: R006
    functools.partial(_round_step, None))


@jax.jit  # expect: R006
def round_step_decorated(state):
    return state * 2.0


@jax.jit(static_argnames=("cfg",))  # expect: R006
def serve_round_step(cfg, state):
    return state + 1.0
