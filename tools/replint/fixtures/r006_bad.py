"""R006 fixture: jits of streaming round steps that fail to donate."""
import functools

import jax
import jax.numpy as jnp


def _round_step(cfg, state, keys):
    return state + jnp.tanh(keys), {"round_time": jnp.sum(state)}


step = jax.jit(_round_step, static_argnames=("cfg",))  # expect: R006

partial_step = jax.jit(  # expect: R006
    functools.partial(_round_step, None))


@jax.jit  # expect: R006
def round_step_decorated(state):
    return state * 2.0


@jax.jit(static_argnames=("cfg",))  # expect: R006
def serve_round_step(cfg, state):
    return state + 1.0


# the FL-workload round step: the (state, keys, row, plan) signature of
# repro.core.serve once model buffers ride in the ServeState — the plan
# row is fresh host data each round, but the state must still be donated
def _fl_round_step(fcfg, state, keys, plan):
    return state + jnp.tanh(keys) * plan, {"fl_loss": jnp.tanh(state)}


fl_step = jax.jit(_fl_round_step, static_argnames=("fcfg",))  # expect: R006


class _Shard:
    def shard_map(self, fn, specs):
        return fn


# sharded serve idiom: jit of a shard_map-wrapped round step still owes
# the donation — the twin-sharded model buffers double all the same
sharded_step = jax.jit(  # expect: R006
    _Shard().shard_map(_fl_round_step, specs=None))
