"""R001 fixture: named oracles may keep the dense path; bare eye is fine."""
import jax.numpy as jnp


def per_bs_work_onehot(assoc, vals, m):
    # reference oracle: the *_onehot suffix licenses the dense contraction
    onehot = jnp.eye(m)[assoc]
    return onehot.T @ vals


def twin_counts_oracle(assoc, m):
    return jnp.sum(jnp.eye(m)[assoc], axis=0)


def identity_block(m):
    # an identity matrix that is never a membership mask is not a one-hot
    return jnp.eye(m)
