"""R006 fixture: donating round-step jits and out-of-scope jits stay clean."""
import functools

import jax
import jax.numpy as jnp


def _round_step(cfg, state, keys):
    return state + jnp.tanh(keys), {"round_time": jnp.sum(state)}


# the serve idiom: state (arg 1 after the static cfg) is donated
step = jax.jit(_round_step, static_argnames=("cfg",), donate_argnums=(1,))

partial_step = jax.jit(functools.partial(_round_step, None),
                       donate_argnums=(0,))


@jax.jit(donate_argnames=("state",))
def round_step_decorated(state):
    return state * 2.0


def train_step(params, batch):
    return params


# non-round-step jits keep their own donation policy — out of scope
plain = jax.jit(train_step)


@jax.jit
def update_step(x):
    return x + 1.0
