"""R006 fixture: donating round-step jits and out-of-scope jits stay clean."""
import functools

import jax
import jax.numpy as jnp


def _round_step(cfg, state, keys):
    return state + jnp.tanh(keys), {"round_time": jnp.sum(state)}


# the serve idiom: state (arg 1 after the static cfg) is donated
step = jax.jit(_round_step, static_argnames=("cfg",), donate_argnums=(1,))

partial_step = jax.jit(functools.partial(_round_step, None),
                       donate_argnums=(0,))


@jax.jit(donate_argnames=("state",))
def round_step_decorated(state):
    return state * 2.0


def _fl_round_step(fcfg, state, keys, plan):
    return state + jnp.tanh(keys) * plan, {"fl_loss": jnp.tanh(state)}


# the FL serve idiom (repro.core.serve with model buffers in ServeState):
# cfg/scfg static, state donated, keys/plan-row passed fresh each round
fl_step = jax.jit(_fl_round_step, static_argnames=("fcfg",),
                  donate_argnums=(1,))


class _Shard:
    def shard_map(self, fn, specs):
        return fn


# sharded FL serve idiom: the shard_map wrapper's first positional arg is
# the donated state, so donate_argnums=(0,) on the jit
sharded_fl_step = jax.jit(_Shard().shard_map(_fl_round_step, specs=None),
                          donate_argnums=(0,))


def train_step(params, batch):
    return params


# non-round-step jits keep their own donation policy — out of scope
plain = jax.jit(train_step)


@jax.jit
def update_step(x):
    return x + 1.0
