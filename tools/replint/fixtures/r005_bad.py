"""R005 fixture: scan carries that change structure or dtype across steps."""
import jax
import jax.numpy as jnp


def carry_grows(xs):
    def body(carry, x):
        acc, count = carry
        return (acc + x, count + 1, x), acc  # expect: R005

    return jax.lax.scan(body, (jnp.zeros(()), jnp.int32(0)), xs)


def init_mismatch(xs):
    def body(carry, x):
        acc, count, last = carry
        return (acc + x, count + 1, x), acc

    return jax.lax.scan(body, (jnp.zeros(()), jnp.int32(0)), xs)  # expect: R005


def carry_dtype_drift(xs):
    def body(carry, x):
        nxt = carry + x
        return nxt.astype(jnp.float32), nxt  # expect: R005

    return jax.lax.scan(body, jnp.zeros((), jnp.bfloat16), xs)


def missing_ys(xs):
    def body(carry, x):
        return carry + x  # expect: R005

    return jax.lax.scan(body, jnp.zeros(()), xs)
