"""replint core: source model, pragma handling, rule registry, runners.

Everything is stdlib-only (``ast``, ``re``, ``pathlib``) so the linter can
run in any environment the repo runs in — including the CI lint job before
any scientific dependency is installed.

Vocabulary
----------
``SourceFile``
    One parsed Python file: source text, AST, module name, and its pragma
    tables (per-line and per-file ``# replint: disable=...`` suppressions).
``Project``
    The set of ``SourceFile``\\ s a run analyzes together. Rules that need
    cross-file knowledge (the R003 traced-reachability call graph) get it
    from here; single-file rules just walk ``sf.tree``.
``Rule``
    Subclass with class attrs ``id`` (``"R00x"``), ``name`` (kebab slug),
    ``description``, and a ``check(sf, project) -> Iterable[Finding]``.
    Decorate with :func:`register` to enter the registry.

Pragmas
-------
``# replint: disable=R001`` on the *reported line* suppresses that rule
there (comma-separate several ids; ``all`` suppresses every rule).
``# replint: disable-file=R003`` anywhere in a file suppresses the rule
for the whole file — the per-module allowlist (e.g. host-side-by-design
modules under R003). Suppressed findings are counted and reported in the
summary so allowlists stay visible.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(
    r"#\s*replint:\s*(disable|disable-file)\s*=\s*([A-Za-z0-9_,\- ]+)")

#: Default scan roots of ``python -m tools.replint`` (repo-relative).
DEFAULT_PATHS = ("src", "examples", "benchmarks")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at ``path:line``."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _relpath(path: pathlib.Path, root: Optional[pathlib.Path]) -> str:
    try:
        return str(path.relative_to(root)) if root else str(path)
    except ValueError:
        return str(path)


class SourceFile:
    """A parsed source file plus its pragma tables."""

    def __init__(self, path: pathlib.Path, root: Optional[pathlib.Path] = None):
        self.path = path
        self.rel = _relpath(path, root)
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        self.module = self._module_name()
        self.line_pragmas: Dict[int, Set[str]] = {}
        self.file_pragmas: Set[str] = set()
        self._scan_pragmas()

    def _module_name(self) -> str:
        parts = pathlib.Path(self.rel).with_suffix("").parts
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def _scan_pragmas(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = PRAGMA_RE.search(line)
            if not m:
                continue
            kind, ids = m.group(1), {
                s.strip() for s in m.group(2).split(",") if s.strip()}
            if kind == "disable":
                self.line_pragmas.setdefault(i, set()).update(ids)
            else:
                self.file_pragmas.update(ids)

    def suppressed(self, rule_id: str, line: int) -> bool:
        if {rule_id, "all"} & self.file_pragmas:
            return True
        at = self.line_pragmas.get(line, ())
        return rule_id in at or "all" in at


class Project:
    """The file set one replint run analyzes together."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)
        self.by_module: Dict[str, SourceFile] = {
            f.module: f for f in self.files if f.module}
        #: files that failed to parse, surfaced as non-suppressible findings
        self.broken: List[Finding] = []
        self._callgraph = None

    @classmethod
    def from_paths(cls, paths: Sequence[str],
                   root: Optional[pathlib.Path] = None) -> "Project":
        root = root or pathlib.Path.cwd()
        files = []
        for p in paths:
            pp = (root / p) if not pathlib.Path(p).is_absolute() \
                else pathlib.Path(p)
            if pp.is_dir():
                files.extend(sorted(pp.rglob("*.py")))
            elif pp.suffix == ".py":
                files.append(pp)
            else:
                raise FileNotFoundError(f"no such file or directory: {p}")
        sources, broken = [], []
        for f in files:
            try:
                sources.append(SourceFile(f, root=root))
            except SyntaxError as e:
                broken.append(Finding(
                    path=_relpath(f, root), line=e.lineno or 0,
                    col=e.offset or 0, rule="SYNTAX",
                    message=f"cannot parse: {e.msg}"))
        project = cls(sources)
        project.broken = broken
        return project

    @property
    def callgraph(self):
        """Lazily-built :class:`tools.replint.callgraph.CallGraph`."""
        if self._callgraph is None:
            from tools.replint.callgraph import CallGraph
            self._callgraph = CallGraph(self)
        return self._callgraph


class Rule:
    """Base class for replint rules. Subclass, set the class attrs, and
    implement :meth:`check`; decorate with :func:`register`."""
    id: str = ""
    name: str = ""
    description: str = ""

    def check(self, sf: SourceFile, project: Project) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, node: ast.AST, message: str) -> Finding:
        return Finding(path=sf.rel, line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0) + 1,
                       rule=self.id, message=message)


RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the registry (id-unique)."""
    inst = cls()
    if inst.id in RULES:
        raise ValueError(f"duplicate rule id {inst.id}")
    RULES[inst.id] = inst
    return cls


def _load_rules() -> None:
    # importing the package registers every rule module exactly once
    import tools.replint.rules  # noqa: F401


def run_project(project: Project) -> Tuple[List[Finding], int]:
    """All findings over a project: ``(reported, n_suppressed)``."""
    _load_rules()
    reported: List[Finding] = list(project.broken)
    suppressed = 0
    seen: Set[Tuple[str, int, int, str]] = set()
    for sf in project.files:
        for rule in RULES.values():
            for f in rule.check(sf, project):
                at = (f.path, f.line, f.col, f.rule)
                if at in seen:
                    continue  # e.g. a site walked by two nested contexts
                seen.add(at)
                if sf.suppressed(f.rule, f.line):
                    suppressed += 1
                else:
                    reported.append(f)
    reported.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return reported, suppressed


def run_paths(paths: Sequence[str],
              root: Optional[pathlib.Path] = None) -> Tuple[List[Finding], int]:
    return run_project(Project.from_paths(paths, root=root))


# ---------------------------------------------------------------------------
# fixture self-tests
# ---------------------------------------------------------------------------

EXPECT_RE = re.compile(r"#\s*expect:\s*([A-Za-z0-9_, ]+)")


def fixture_dir() -> pathlib.Path:
    return pathlib.Path(__file__).parent / "fixtures"


def _expected(sf: SourceFile) -> Set[Tuple[str, int]]:
    out: Set[Tuple[str, int]] = set()
    for i, line in enumerate(sf.lines, start=1):
        m = EXPECT_RE.search(line)
        if m:
            out.update((r.strip(), i) for r in m.group(1).split(",")
                       if r.strip())
    return out


def run_selftest(out=sys.stdout) -> int:
    """Prove every rule fires on its known-bad fixture lines and stays
    silent on the matching known-good file.

    Each fixture file is analyzed as its own single-file project (so bad
    files cannot leak traced entries or definitions into good ones).
    ``# expect: R00x`` marks a line that must produce exactly that finding;
    a fixture with no expectations must come back clean. Returns the number
    of failures (0 == pass).
    """
    _load_rules()
    failures = 0
    files = sorted(fixture_dir().rglob("*.py"))
    if not files:
        print("replint selftest: no fixtures found", file=out)
        return 1
    rules_fired: Set[str] = set()
    for path in files:
        sf = SourceFile(path, root=fixture_dir())
        findings, _ = run_project(Project([sf]))
        got = {(f.rule, f.line) for f in findings}
        want = _expected(sf)
        rules_fired.update(r for r, _ in got)
        for miss in sorted(want - got):
            failures += 1
            print(f"FAIL {sf.rel}: expected {miss[0]} at line {miss[1]}, "
                  f"not reported", file=out)
        for extra in sorted(got - want):
            failures += 1
            print(f"FAIL {sf.rel}: unexpected {extra[0]} at line {extra[1]}",
                  file=out)
    for rule_id in sorted(RULES):
        if rule_id not in rules_fired:
            failures += 1
            print(f"FAIL registry: rule {rule_id} never fired on any "
                  f"fixture", file=out)
    status = "ok" if failures == 0 else f"{failures} failure(s)"
    print(f"replint selftest: {len(files)} fixtures, {len(RULES)} rules "
          f"— {status}", file=out)
    return failures
