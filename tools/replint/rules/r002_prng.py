"""R002 prng-key-reuse: a PRNG key consumed by two ``jax.random`` calls.

Key discipline is what keeps the single-device-vs-sharded parity gates at
<= 5e-7 (``core/sharding.py`` / ``core/migration.py`` slice *the same
global draw* per shard — feed two samplers from one key and the paths
decorrelate silently). The rule runs an intra-function, flow-ordered
dataflow pass:

* consuming calls: every ``jax.random.*`` sampler (``uniform``,
  ``normal``, ``gumbel``, ...) **and** ``split`` — sampling from a key
  that was already split (or splitting twice) overlaps the streams;
* non-consuming: ``PRNGKey`` (creates), ``fold_in`` (the sanctioned
  per-index derivation — ``fold_in(key, i)`` in a loop is the idiom the
  repo uses for paired comparisons), key metadata helpers;
* any rebinding of the name (``key, sub = jax.random.split(key)``) makes
  it fresh again.

Keys are tracked as plain names, attribute chains (``ts.key``) and
constant subscripts (``ks[0]``). ``if``/``else`` branches fork the state
and merge; ``for``/``while`` bodies (and comprehensions) are analyzed
twice so a consume of a loop-invariant key is caught on the simulated
second iteration.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional, Set, Tuple

from tools.replint.callgraph import dotted, last_name
from tools.replint.engine import Project, Rule, SourceFile, register

_NONCONSUMING = {"PRNGKey", "fold_in", "key_data", "wrap_key_data",
                 "key_impl", "clone"}
_SKIP_ROOTS = {"np", "numpy", "self"}


def _is_jax_random_call(node: ast.Call) -> bool:
    path = dotted(node.func)
    if path is None:
        return False
    parts = path.split(".")
    if parts[0] in _SKIP_ROOTS:
        return False
    # jax.random.uniform / random.uniform / jr.normal / jrandom.normal
    return "random" in parts[:-1] or parts[0] in {"jr", "jrandom"}


def _key_expr(node: ast.AST) -> Optional[str]:
    """Stable identifier for a key-valued expression, or None."""
    path = dotted(node)
    if path is not None:
        return path
    if isinstance(node, ast.Subscript) and isinstance(
            node.slice, ast.Constant):
        base = _key_expr(node.value)
        if base is not None:
            return f"{base}[{node.slice.value!r}]"
    return None


def _terminates(body) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


class _KeyFlow:
    """Flow-ordered consumed-key tracking over one function body."""

    def __init__(self, rule: Rule, sf: SourceFile):
        self.rule = rule
        self.sf = sf
        self.consumed: Dict[str, ast.AST] = {}  # key expr -> consuming node
        self.findings: Dict[Tuple[int, int, str], ast.AST] = {}

    # -- state helpers ------------------------------------------------------
    def _rebind(self, target: ast.AST) -> None:
        for node in ast.walk(target):
            expr = _key_expr(node)
            if expr is not None:
                # rebinding a base name refreshes its subscript views too
                for known in list(self.consumed):
                    if known == expr or known.startswith(expr + "["):
                        del self.consumed[known]

    def _consume(self, expr: str, node: ast.AST) -> None:
        if expr in self.consumed:
            self.findings[(node.lineno, node.col_offset, expr)] = node
        self.consumed[expr] = node

    # -- traversal ----------------------------------------------------------
    def run(self, body) -> None:
        for stmt in body:
            self.stmt(stmt)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # separate scope, analyzed on its own
        if isinstance(node, ast.Assign):
            self.expr(node.value)
            for t in node.targets:
                self._rebind(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            if node.value is not None:
                self.expr(node.value)
            self._rebind(node.target)
        elif isinstance(node, ast.If):
            self.expr(node.test)
            self._branches(node.body, node.orelse)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            self.expr(node.iter)
            self._loop(node.body, rebinds=[node.target])
            self.run(node.orelse)
        elif isinstance(node, ast.While):
            self.expr(node.test)
            self._loop(node.body)
            self.run(node.orelse)
        elif isinstance(node, ast.With):
            for item in node.items:
                self.expr(item.context_expr)
            self.run(node.body)
        elif isinstance(node, ast.Try):
            self.run(node.body)
            for h in node.handlers:
                self.run(h.body)
            self.run(node.orelse)
            self.run(node.finalbody)
        elif isinstance(node, ast.Return):
            if node.value is not None:
                self.expr(node.value)
        elif isinstance(node, ast.Expr):
            self.expr(node.value)
        else:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self.expr(child)

    def _branches(self, *bodies) -> None:
        before = dict(self.consumed)
        merged: Dict[str, ast.AST] = dict(before)
        for body in bodies:
            self.consumed = dict(before)
            self.run(body)
            # a branch that returns/raises never rejoins the fall-through,
            # so its consumptions must not poison the merged state
            if not _terminates(body):
                merged.update(self.consumed)
        self.consumed = merged

    def _loop(self, body, rebinds=()) -> None:
        # two passes simulate the second iteration: a consume of a
        # loop-invariant key shows up as a re-consume on pass 2, while the
        # loop target itself is rebound fresh every iteration
        for _ in range(2):
            for target in rebinds:
                self._rebind(target)
            self.run(body)

    def expr(self, node: ast.expr) -> None:
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            for gen in node.generators:
                self.expr(gen.iter)
            inner = [ast.Expr(value=node.elt)] if not isinstance(
                node, ast.DictComp) else [ast.Expr(value=node.key),
                                          ast.Expr(value=node.value)]
            self._loop(inner,
                       rebinds=[gen.target for gen in node.generators])
            return
        if isinstance(node, ast.Call):
            # evaluate arguments first (inner calls consume before outer)
            for arg in node.args:
                self.expr(arg)
            for kw in node.keywords:
                self.expr(kw.value)
            if _is_jax_random_call(node) and \
                    last_name(node.func) not in _NONCONSUMING and node.args:
                expr = _key_expr(node.args[0])
                if expr is not None:
                    self._consume(expr, node)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.expr(child)


@register
class PrngKeyReuse(Rule):
    id = "R002"
    name = "prng-key-reuse"
    description = ("a PRNG key fed to two jax.random calls without an "
                   "intervening split/fold_in rebind")

    def check(self, sf: SourceFile, project: Project):
        cg = project.callgraph
        for fi in cg.functions_in(sf.module):
            node = fi.node
            if isinstance(node, ast.Lambda):
                continue
            flow = _KeyFlow(self, sf)
            flow.run(node.body)
            for (_, _, expr), call in sorted(flow.findings.items()):
                yield self.finding(
                    sf, call,
                    f"PRNG key {expr!r} is consumed again here — keys are "
                    f"single-use; derive fresh ones with jax.random.split "
                    f"or fold_in first")
        # module-level statements (scripts, benchmarks)
        flow = _KeyFlow(self, sf)
        flow.run([s for s in sf.tree.body
                  if not isinstance(s, (ast.FunctionDef,
                                        ast.AsyncFunctionDef, ast.ClassDef))])
        for (_, _, expr), call in sorted(flow.findings.items()):
            yield self.finding(
                sf, call,
                f"PRNG key {expr!r} is consumed again here — keys are "
                f"single-use; derive fresh ones with jax.random.split "
                f"or fold_in first")
