"""Rule registry — importing this package registers every rule.

One module per rule; each module defines a :class:`tools.replint.engine.Rule`
subclass decorated with :func:`tools.replint.engine.register`. To add a
rule, drop an ``r0xx_*.py`` module here, import it below, and give it a
fixture pair under ``tools/replint/fixtures/`` (the selftest fails any
registered rule that never fires on a fixture).
"""
from tools.replint.rules import (r001_onehot, r002_prng, r003_hostsync,
                                 r004_sharding_scope, r005_scan_carry,
                                 r006_donate_round_step)  # noqa: F401
