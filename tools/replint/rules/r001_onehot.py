"""R001 no-dense-onehot: ban ``jnp.eye(M)[assoc]``-style contractions.

Every per-BS reduction must route through the unified
``repro/kernels/segment_reduce.py`` dispatch (O(N+M) memory) instead of
materializing the dense (N, M) one-hot membership mask the seed used
(O(N*M) — dead at N=10^6 twins). Dense paths are allowed only as named
numerical oracles: any enclosing function whose name ends in ``_onehot``
or ``_oracle`` (e.g. the Eq. 12-17 reference paths in
``src/repro/core/latency.py`` and the ``_seg_onehot`` parity backend).
"""
from __future__ import annotations

import ast

from tools.replint.callgraph import last_name
from tools.replint.engine import Finding, Project, Rule, SourceFile, register

_EYE_ROOTS = {"jnp", "np", "numpy", "jax"}
_ORACLE_SUFFIXES = ("_onehot", "_oracle")


def _is_eye_call(node: ast.AST) -> bool:
    if not (isinstance(node, ast.Call) and last_name(node.func) == "eye"):
        return False
    root = node.func
    while isinstance(root, ast.Attribute):
        root = root.value
    return isinstance(root, ast.Name) and root.id in _EYE_ROOTS


def _in_oracle(sf: SourceFile, project: Project, node: ast.AST) -> bool:
    fi = project.callgraph.owner_of(sf.module, node)
    while fi is not None:
        if fi.name.endswith(_ORACLE_SUFFIXES):
            return True
        fi = project.callgraph.modules[fi.module].functions.get(fi.parent) \
            if fi.parent else None
    return False


@register
class NoDenseOnehot(Rule):
    id = "R001"
    name = "no-dense-onehot"
    description = ("dense jnp.eye(M)[assoc] one-hot contraction outside a "
                   "*_onehot/*_oracle function — use "
                   "repro.kernels.segment_reduce instead")

    def check(self, sf: SourceFile, project: Project):
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Subscript)
                    and _is_eye_call(node.value)):
                continue
            if _in_oracle(sf, project, node):
                continue
            yield self.finding(
                sf, node,
                "dense one-hot contraction (jnp.eye(...)[assoc]) is "
                "O(N*M); route per-BS reductions through "
                "repro.kernels.segment_reduce (or name the function "
                "*_onehot/*_oracle if it is a reference path)")
