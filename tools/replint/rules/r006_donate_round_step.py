"""R006 round-step-must-donate: every jit of a streaming round step donates.

The always-on serve loop's memory story rests on
``jax.jit(_round_step, donate_argnums=...)`` (``repro.core.serve``):
donation lets XLA alias round t+1's ServeState into round t's buffers, so
the N-sized twin arrays live on device once. A jit of a round step WITHOUT
``donate_argnums`` silently doubles the service's device footprint — every
round allocates a fresh N-sized state next to the old one — and nothing
fails; the regression only shows up as an OOM at the N=10^6 scale the
streaming path exists for.

Scope is deliberately narrow: only ``jax.jit`` applications (call form,
``functools.partial`` wrapping, or decorator form) of a function whose
name contains ``round_step`` — the streaming-step naming convention. Batch
train/update steps and bench jits keep their own donation policies and are
not flagged.
"""
from __future__ import annotations

import ast

from tools.replint.callgraph import dotted, last_name, unwrap_partial
from tools.replint.engine import Project, Rule, SourceFile, register

_NEEDLE = "round_step"


def _is_jit(func: ast.AST) -> bool:
    path = dotted(func)
    return path == "jax.jit" or (path is None and last_name(func) == "jit") \
        or path == "jit"


def _target_name(node: ast.AST) -> str:
    """Best-effort name of the function a jit call wraps."""
    node = unwrap_partial(node)
    if isinstance(node, ast.Call):  # e.g. ts.shard_map(local, ...)
        for arg in node.args:
            name = last_name(unwrap_partial(arg))
            if name:
                return name
        return ""
    return last_name(node) or ""


def _donates(call: ast.Call) -> bool:
    return any(kw.arg == "donate_argnums" or kw.arg == "donate_argnames"
               for kw in call.keywords)


@register
class RoundStepMustDonate(Rule):
    id = "R006"
    name = "round-step-must-donate"
    description = ("jax.jit of a *round_step* function without "
                   "donate_argnums — the streaming state must be donated "
                   "or every round allocates a second N-sized ServeState")

    def check(self, sf: SourceFile, project: Project):
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) and _is_jit(node.func):
                if not node.args:
                    continue
                if _NEEDLE in _target_name(node.args[0]) \
                        and not _donates(node):
                    yield self.finding(
                        sf, node,
                        f"jax.jit({_target_name(node.args[0])}, ...) "
                        "without donate_argnums — a streaming round step "
                        "must donate its state argument (see "
                        "repro.core.serve)")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _NEEDLE in node.name:
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and _is_jit(dec.func) \
                            and not _donates(dec):
                        yield self.finding(
                            sf, dec,
                            f"@jax.jit on {node.name!r} without "
                            "donate_argnums — a streaming round step must "
                            "donate its state argument")
                    elif not isinstance(dec, ast.Call) and _is_jit(dec):
                        yield self.finding(
                            sf, dec,
                            f"bare @jax.jit on {node.name!r} cannot donate "
                            "— use jax.jit(fn, donate_argnums=...) for a "
                            "streaming round step")
