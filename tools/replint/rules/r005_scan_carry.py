"""R005 scan-carry-hygiene: ``lax.scan`` carries must keep structure/dtype.

``jax.lax.scan`` requires the carry pytree to have identical structure,
shape, and dtype on entry and exit of the body — violations surface as
opaque "scan carry has wrong pytree structure / dtype mismatch" trace
errors, and the sharded trainer adds a second failure mode: the PR 4
rep-stamping of carries (``sharding.stamp_replicated``) only lines up when
the body returns exactly the structure it received. Statically checkable
slices of that contract:

* a scan body must return a 2-tuple ``(carry, aux)`` — returning a bare
  carry or a 3-tuple mis-nests the carry into the stacked outputs;
* when both the ``init`` argument and the body's returned carry are tuple
  literals, their lengths must match;
* the returned carry expression must not cast values derived from the
  carry parameter (``.astype(...)`` / ``jnp.float32(...)`` and friends) —
  a dtype change relative to the init fails the trace; cast the *init*
  once instead.

Bodies wrapped in ``functools.partial`` are unwrapped (bound positional
args shift which parameter is the carry); bodies that cannot be resolved
statically (e.g. conditional ``body_fn = jax.checkpoint(body) if ...``)
are skipped.
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.replint.callgraph import (dotted, last_name, partial_bound_args,
                                     unwrap_partial)
from tools.replint.engine import Project, Rule, SourceFile, register

_DTYPE_CTORS = {"float16", "float32", "float64", "bfloat16", "int8", "int16",
                "int32", "int64", "uint8", "uint16", "uint32", "uint64"}


def _is_scan_call(node: ast.Call) -> bool:
    if last_name(node.func) != "scan":
        return False
    path = dotted(node.func) or "scan"
    root = path.split(".")[0]
    return root in {"jax", "lax", "scan"} or "lax" in path.split(".")


def _mentions(node: ast.AST, name: str) -> bool:
    return any(isinstance(s, ast.Name) and s.id == name
               for s in ast.walk(node))


def _carry_param(fn: ast.AST, bound: int) -> Optional[str]:
    args = fn.args.args
    if bound < len(args):
        return args[bound].arg
    return None


def _unpack_arity(fn: ast.AST, carry_name: Optional[str]) -> Optional[int]:
    """Arity of ``a, b = carry`` inside the body, if present."""
    if carry_name is None or isinstance(fn, ast.Lambda):
        return None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], (ast.Tuple, ast.List)) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == carry_name:
            return len(node.targets[0].elts)
    return None


def _dtype_name(node: Optional[ast.AST]) -> Optional[str]:
    """Dtype spelled by ``jnp.float32`` / ``'float32'``, else None."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    name = last_name(node)
    return name if name in _DTYPE_CTORS else None


def _init_dtype(init: Optional[ast.AST]) -> Optional[str]:
    """Dtype of the scan init, when spelled literally."""
    if not isinstance(init, ast.Call):
        return None
    name = last_name(init.func)
    if name in _DTYPE_CTORS:
        return name
    for kw in init.keywords:
        if kw.arg == "dtype":
            return _dtype_name(kw.value)
    if name in {"zeros", "ones", "empty"} and len(init.args) > 1:
        return _dtype_name(init.args[1])
    if name == "full" and len(init.args) > 2:
        return _dtype_name(init.args[2])
    return None


# expression kinds that can never evaluate to the required (carry, aux) pair
_NEVER_TUPLE = (ast.BinOp, ast.UnaryOp, ast.Compare, ast.Constant,
                ast.Dict, ast.Set, ast.JoinedStr)


@register
class ScanCarryHygiene(Rule):
    id = "R005"
    name = "scan-carry-hygiene"
    description = ("lax.scan body changes the carry's structure or dtype "
                   "(or does not return a (carry, aux) 2-tuple)")

    def check(self, sf: SourceFile, project: Project):
        cg = project.callgraph
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call) and _is_scan_call(node)):
                continue
            if not node.args:
                continue
            owner = cg.owner_of(sf.module, node)
            scope = owner.qual if owner else None
            body_expr = node.args[0]
            bound = partial_bound_args(body_expr)
            fi = cg.resolve(sf.module, scope, unwrap_partial(body_expr))
            if fi is None:
                continue
            init = node.args[1] if len(node.args) > 1 else None
            yield from self._check_body(sf, fi.node, bound, init)

    def _check_body(self, sf: SourceFile, fn: ast.AST, bound: int, init):
        carry_name = _carry_param(fn, bound)
        unpack = _unpack_arity(fn, carry_name)
        if isinstance(fn, ast.Lambda):
            returns = [(fn.body, fn.body)]
        else:
            returns = [(r, r.value) for r in ast.walk(fn)
                       if isinstance(r, ast.Return) and r.value is not None]
        for anchor, value in returns:
            if isinstance(value, _NEVER_TUPLE):
                yield self.finding(
                    sf, anchor,
                    "scan body must return a (carry, aux) 2-tuple — this "
                    "returns a bare expression; add an aux slot "
                    "(e.g. `return carry, None`)")
                continue
            if not isinstance(value, (ast.Tuple, ast.List)):
                continue  # a Name/Call return — not statically checkable
            if len(value.elts) != 2:
                yield self.finding(
                    sf, anchor,
                    f"scan body must return (carry, aux) — got a "
                    f"{len(value.elts)}-tuple; wrap auxiliary outputs in "
                    f"one pytree")
                continue
            carry_expr = value.elts[0]
            ret_arity = len(carry_expr.elts) if isinstance(
                carry_expr, (ast.Tuple, ast.List)) else None
            if ret_arity is not None and unpack is not None and \
                    unpack != ret_arity:
                # the body itself is inconsistent: unpacks one shape,
                # returns another — anchor at the return
                yield self.finding(
                    sf, carry_expr,
                    f"scan carry structure changed: the body unpacks a "
                    f"{unpack}-tuple carry but returns a {ret_arity}-tuple "
                    f"— the carry pytree must be invariant across "
                    f"iterations")
            elif ret_arity is not None and isinstance(
                    init, (ast.Tuple, ast.List)) and \
                    len(init.elts) != ret_arity:
                # body is self-consistent; the init disagrees — anchor
                # at the scan call's init argument
                yield self.finding(
                    sf, init,
                    f"scan init is a {len(init.elts)}-tuple but the body "
                    f"carries a {ret_arity}-tuple — the carry pytree must "
                    f"match the init")
            yield from self._check_dtype_casts(sf, carry_expr, carry_name,
                                               _init_dtype(init))

    def _check_dtype_casts(self, sf: SourceFile, carry_expr: ast.AST,
                           carry_name: Optional[str],
                           init_dtype: Optional[str]):
        for sub in ast.walk(carry_expr):
            if not isinstance(sub, ast.Call):
                continue
            name = last_name(sub.func)
            is_cast = (name == "astype"
                       or (name in _DTYPE_CTORS
                           and (dotted(sub.func) or "").split(".")[0]
                           in {"jnp", "np", "numpy", "jax"}))
            if not is_cast:
                continue
            cast_dtype = _dtype_name(sub.args[0]) if name == "astype" \
                and sub.args else (name if name in _DTYPE_CTORS else None)
            if init_dtype is not None and cast_dtype is not None:
                if cast_dtype != init_dtype:
                    yield self.finding(
                        sf, sub,
                        f"returned scan carry is cast to {cast_dtype} but "
                        f"the init is {init_dtype} — the carry dtype must "
                        f"match the init on every iteration")
                continue
            target = sub.func.value if isinstance(sub.func, ast.Attribute) \
                else (sub.args[0] if sub.args else sub)
            if carry_name is None or _mentions(target, carry_name):
                yield self.finding(
                    sf, sub,
                    "dtype cast in the returned scan carry — the carry "
                    "dtype must match the init on every iteration; cast "
                    "the init once before the scan instead")
