"""R003 host-sync-in-traced-code: no device->host sync inside traced code.

A ``float()``/``int()`` cast, ``.item()``, ``np.asarray`` or
``.block_until_ready()`` on an array value inside a jitted region either
fails to trace (TracerArrayConversionError) or — worse, when it survives
via a leaked concrete value — blocks dispatch and silently serializes the
round step the latency claims rest on. The rule flags host-sync operations
inside any function the call graph marks *traced-reachable*
(``tools.replint.callgraph``: reachable from a ``jax.jit`` / ``shard_map``
/ ``lax.scan`` / ... entry).

Array-ness is approximated by local dataflow: a name is array-like when it
was assigned from a ``jnp.*`` / ``jax.*`` / ``lax.*`` expression or from
the segment-reduce / twin-scope primitives. ``float(x.shape[0])``-style
static-shape arithmetic therefore stays legal, which is exactly the
trace-time computation jitted code is allowed to do. Host-side-by-design
modules (e.g. ``repro/core/blockchain.py``) sit outside the traced call
graph; if one ever gets pulled in, allowlist it with a file pragma
(``# replint: disable-file=R003``).
"""
from __future__ import annotations

import ast
from typing import Set

from tools.replint.callgraph import FuncInfo, dotted, last_name
from tools.replint.engine import Project, Rule, SourceFile, register

_ARRAY_ROOTS = {"jnp", "lax", "jax"}
_ARRAY_FUNCS = {"segment_reduce", "segment_count", "twin_sum", "twin_mean",
                "twin_max", "twin_min", "twin_std", "twin_softmax_pool",
                "bs_sum", "twin_counts"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_CAST_FUNCS = {"float", "int", "bool", "complex"}
_NP_SYNC = {"asarray", "array"}
_REDUCE_METHODS = {"sum", "mean", "max", "min", "prod", "std", "var", "all",
                   "any"}


def _mentions_array_source(node: ast.AST, arraylike: Set[str]) -> bool:
    """Does this expression involve an array-like name or a jnp/jax call?"""
    for sub in ast.walk(node):
        path = dotted(sub)
        if path is not None:
            root = path.split(".")[0]
            if root in _ARRAY_ROOTS and "." in path:
                return True
            if path in arraylike or root in arraylike:
                return True
        if isinstance(sub, ast.Call):
            name = last_name(sub.func)
            if name in _ARRAY_FUNCS:
                return True
            if name in _REDUCE_METHODS and isinstance(
                    sub.func, ast.Attribute) and _mentions_array_source(
                        sub.func.value, arraylike):
                return True
    return False


def _collect_arraylike(fn: ast.AST) -> Set[str]:
    """Names assigned (anywhere in the function) from array expressions."""
    arraylike: Set[str] = set()
    for _ in range(2):  # two passes: propagate through one chained assign
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and sub is not fn:
                continue
            targets = ()
            value = None
            if isinstance(sub, ast.Assign):
                targets, value = sub.targets, sub.value
            elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
                targets, value = (sub.target,), sub.value
            if value is None or not _mentions_array_source(value, arraylike):
                continue
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        arraylike.add(leaf.id)
    return arraylike


def _np_call(node: ast.Call) -> bool:
    path = dotted(node.func)
    return (path is not None
            and path.split(".")[0] in {"np", "numpy", "onp"}
            and last_name(node.func) in _NP_SYNC)


@register
class HostSyncInTracedCode(Rule):
    id = "R003"
    name = "host-sync-in-traced-code"
    description = ("float()/int()/.item()/np.asarray/.block_until_ready on "
                   "an array value inside a traced-reachable function")

    def check(self, sf: SourceFile, project: Project):
        cg = project.callgraph
        for fi in cg.functions_in(sf.module):
            if not cg.is_reachable(fi):
                continue
            if isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_function(sf, fi)

    def _check_function(self, sf: SourceFile, fi: FuncInfo):
        fn = fi.node
        arraylike = _collect_arraylike(fn)
        for node in ast.walk(fn):
            # skip nested defs — they are their own (reachable) FuncInfos
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            if not isinstance(node, ast.Call):
                continue
            name = last_name(node.func)
            if name in _SYNC_METHODS and isinstance(node.func, ast.Attribute):
                yield self.finding(
                    sf, node,
                    f".{name}() forces a device->host sync inside traced "
                    f"code (function {fi.qual!r} is reachable from a "
                    f"jit/shard_map/scan entry)")
            elif name == "device_get" and isinstance(node.func,
                                                     ast.Attribute):
                yield self.finding(
                    sf, node,
                    f"jax.device_get inside traced-reachable function "
                    f"{fi.qual!r} blocks dispatch — keep the value on "
                    f"device")
            elif _np_call(node) and node.args and _mentions_array_source(
                    node.args[0], arraylike):
                yield self.finding(
                    sf, node,
                    f"np.{name} on a device value inside traced-reachable "
                    f"function {fi.qual!r} — use jnp, or hoist to the host "
                    f"boundary")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in _CAST_FUNCS and node.args
                  and _mentions_array_source(node.args[0], arraylike)):
                yield self.finding(
                    sf, node,
                    f"{node.func.id}() on an array value inside "
                    f"traced-reachable function {fi.qual!r} forces a "
                    f"host sync — keep it a jnp scalar, or hoist it out "
                    f"of the traced region")
