"""R004 sharding-scope-discipline: twin reductions inside sharded regions.

Inside a ``shard_map`` body (or a ``sharded_*`` entry point, or any
function tracing under a ``with ts.scope(...)`` / ``twin_scope(...)``
region) every local array holds only this shard's twin block. A bare
``jnp.sum`` / ``jnp.mean`` / ``jnp.max`` / ``jnp.min`` / ``jnp.std`` over
it silently computes a *per-shard* statistic where the single-device code
computed a population one — the bug class the masked ``twin_*`` helpers in
``repro/core/sharding.py`` exist to prevent (they psum/pmax across the
mesh and mask padding rows). Likewise, ``segment_reduce``/``segment_count``
call sites must not pin ``backend="..."`` where the scope hook should
dispatch: a hard-coded single-device backend skips the cross-shard psum
and returns partial per-BS sums.

The rule is lexical: it applies to functions named ``sharded_*``,
functions passed to a ``shard_map`` call, functions containing a
``with ...scope(...)`` block, and everything nested inside those. The
``twin_*`` helper implementations themselves live outside any such
context, so they lint clean by construction.
"""
from __future__ import annotations

import ast
from typing import Set

from tools.replint.callgraph import dotted, last_name, unwrap_partial
from tools.replint.engine import Project, Rule, SourceFile, register

_CROSS_TWIN_REDUCTIONS = {"sum", "mean", "max", "min", "std"}
_ARRAY_ROOTS = {"jnp", "np", "numpy"}
_SEGMENT_CALLS = {"segment_reduce", "segment_count"}


def _is_scope_with(node: ast.With) -> bool:
    for item in node.items:
        name = last_name(item.context_expr.func) if isinstance(
            item.context_expr, ast.Call) else None
        if name in {"scope", "twin_scope"}:
            return True
    return False


def _sharded_contexts(sf: SourceFile, project: Project) -> Set[str]:
    """Quals of functions that trace inside a twin-sharded region."""
    cg = project.callgraph
    idx = cg.modules.get(sf.module)
    if idx is None:
        return set()
    base: Set[str] = set()
    for fi in idx.functions.values():
        if fi.name.startswith("sharded_"):
            base.add(fi.qual)
        for node in ast.walk(fi.node):
            if isinstance(node, ast.With) and _is_scope_with(node):
                base.add(fi.qual)
                break
    # functions passed to a shard_map(...) call anywhere in this file
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and last_name(node.func) == "shard_map":
            owner = cg.owner_of(sf.module, node)
            scope = owner.qual if owner else None
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                fi = cg.resolve(sf.module, scope, unwrap_partial(arg))
                if fi is not None and fi.module == sf.module:
                    base.add(fi.qual)
    # closure: anything lexically nested inside a context is in context
    changed = True
    while changed:
        changed = False
        for fi in idx.functions.values():
            if fi.qual not in base and fi.parent in base:
                base.add(fi.qual)
                changed = True
    return base


@register
class ShardingScopeDiscipline(Rule):
    id = "R004"
    name = "sharding-scope-discipline"
    description = ("cross-twin jnp reduction or pinned segment_reduce "
                   "backend inside a shard_map / sharded_* region")

    def check(self, sf: SourceFile, project: Project):
        contexts = _sharded_contexts(sf, project)
        if not contexts:
            return
        cg = project.callgraph
        idx = cg.modules[sf.module]
        for qual in sorted(contexts):
            fi = idx.functions.get(qual)
            if fi is None:
                continue
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not fi.node:
                    continue  # nested defs are contexts of their own
                if not isinstance(node, ast.Call):
                    continue
                name = last_name(node.func)
                path = dotted(node.func) or ""
                root = path.split(".")[0] if path else ""
                if (name in _CROSS_TWIN_REDUCTIONS
                        and root in _ARRAY_ROOTS):
                    yield self.finding(
                        sf, node,
                        f"jnp.{name} inside twin-sharded context "
                        f"{qual!r} reduces only this shard's block — use "
                        f"sharding.twin_{name} (masked local reduction + "
                        f"collective) for cross-twin statistics")
                elif name in _SEGMENT_CALLS:
                    for kw in node.keywords:
                        if kw.arg == "backend" and isinstance(
                                kw.value, ast.Constant) and \
                                kw.value.value != "auto":
                            yield self.finding(
                                sf, kw.value,
                                f"{name}(backend={kw.value.value!r}) pinned "
                                f"inside twin-sharded context {qual!r} "
                                f"skips the scope hook's sharded dispatch "
                                f"(local reduce + psum) — leave "
                                f"backend='auto'")
